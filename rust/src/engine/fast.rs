//! The `Fast` tier: native-integer, slice-vectorized engine
//! implementations with event/cycle accounting identical to the
//! gate-level models.
//!
//! Every charge the [`crate::cim`] models make per operation is derived
//! here in closed form instead of being accumulated gate-by-gate:
//!
//! - [`FastDistance`] stores the tile as three coordinate slices (SoA)
//!   and computes a whole scan in one autovectorizable pass; the charges
//!   (one [`Event::ApdDistanceOp`] per point, 48 register bits per
//!   reference readout, row-rate cycles) are the same constants the
//!   APD-CIM model charges per scan.
//! - [`FastMaxSearch`] keeps live TDs as a flat `u32` slice. The MSB-first
//!   bit-CAM search's energy is reproduced analytically: an entry with
//!   live value `v` stays in the search until the first bit position
//!   where its prefix diverges from the maximum's, so its searched-cell
//!   count is `TD_BITS - msb(v XOR max)` (`TD_BITS` when `v == max`) —
//!   one `leading_zeros` per entry instead of 19 array sweeps.
//! - [`FastMac`] computes dot products natively (the split-concatenate
//!   datapath is exact, so `sum(x[i] * w[i])` is the same number) and
//!   reuses the 4-cycles-per-wave cost formula.
//!
//! On top of the per-operation engines, this module holds the
//! median-partition **pruned preprocessing kernels**
//! ([`PrunedPreprocessor`]): FPS and lattice-query rewritten against a
//! [`MedianIndex`] so whole leaf cells are skipped via exact
//! bounding-box L1 lower bounds, while every hardware charge is made in
//! the same closed form the per-operation engines make it — outputs,
//! cycles, ledgers and serve digests stay byte-identical to both engine
//! tiers; only host time drops. The distance work that survives pruning
//! runs through a blocked SoA microkernel (fixed-width unrolled lanes),
//! which also feeds [`FastDistance`]'s
//! [`DistanceEngine::scan_distances_into`] implementation.
//!
//! Bit-identity with the `BitExact` tier — outputs, cycles, ledgers — is
//! enforced by `rust/tests/fidelity_equivalence.rs`.

use super::{DistanceEngine, MacEngine, MaxSearchEngine};
use crate::cim::apd_cim::ApdCimConfig;
use crate::cim::max_cam::CamConfig;
use crate::cim::sc_cim::ScCimConfig;
use crate::cim::sorter::TopKSorter;
use crate::energy::{EnergyLedger, Event};
use crate::quant::{QPoint3, TD_BITS};
use crate::sampling::{GroupsCsr, MedianIndex};

/// Fast-tier distance array: SoA coordinate storage, native `abs_diff`
/// scans, APD-CIM-identical accounting.
#[derive(Debug, Clone)]
pub struct FastDistance {
    cfg: ApdCimConfig,
    xs: Vec<u16>,
    ys: Vec<u16>,
    zs: Vec<u16>,
    cycles: u64,
    ledger: EnergyLedger,
}

impl FastDistance {
    /// An empty array with the given geometry.
    pub fn new(cfg: ApdCimConfig) -> Self {
        Self {
            cfg,
            xs: Vec::new(),
            ys: Vec::new(),
            zs: Vec::new(),
            cycles: 0,
            ledger: EnergyLedger::new(),
        }
    }

    fn scan_cycles(&self, n: usize) -> u64 {
        n.div_ceil(self.cfg.distances_per_cycle()) as u64
    }

    fn scan_to_into(&mut self, r: QPoint3, out: &mut Vec<u32>) {
        // Reference readout into bit-parallel input registers: 48 bits.
        self.ledger.charge(Event::RegBit, 48);
        self.cycles += 1;
        out.clear();
        out.resize(self.xs.len(), 0);
        l1_soa_lanes(&self.xs, &self.ys, &self.zs, r, |k, d| out[k] = d);
        self.ledger.charge(Event::ApdDistanceOp, out.len() as u64);
        self.cycles += self.scan_cycles(out.len());
    }
}

/// Blocked SoA L1-distance microkernel: computes every member's 19-bit
/// L1 distance to `r` from the coordinate lane slices and hands
/// `(member_offset, distance)` to `sink` in increasing-index order.
/// Routed through [`crate::simd::l1_lanes`], which dispatches at runtime
/// between the AVX2, SSE2 and scalar bodies (`--simd` ceiling × cached
/// CPU probe) — all emit identical distances in identical order (exact
/// integer arithmetic), so the choice never reaches cycles, ledgers or
/// digests.
#[inline]
fn l1_soa_lanes(xs: &[u16], ys: &[u16], zs: &[u16], r: QPoint3, sink: impl FnMut(usize, u32)) {
    crate::simd::l1_lanes(xs, ys, zs, r, sink)
}

impl DistanceEngine for FastDistance {
    fn capacity(&self) -> usize {
        self.cfg.capacity()
    }

    fn len(&self) -> usize {
        self.xs.len()
    }

    fn distances_per_cycle(&self) -> usize {
        self.cfg.distances_per_cycle()
    }

    fn load_tile(&mut self, tile: &[QPoint3]) {
        assert!(
            tile.len() <= self.cfg.capacity(),
            "tile of {} exceeds APD-CIM capacity {}",
            tile.len(),
            self.cfg.capacity()
        );
        self.xs.clear();
        self.ys.clear();
        self.zs.clear();
        for p in tile {
            self.xs.push(p.x);
            self.ys.push(p.y);
            self.zs.push(p.z);
        }
        self.ledger.charge(Event::SramBit, tile.len() as u64 * 48);
        self.cycles += self.scan_cycles(tile.len());
    }

    fn scan_distances_into(&mut self, ref_idx: usize, out: &mut Vec<u32>) {
        assert!(ref_idx < self.xs.len(), "reference {ref_idx} not resident");
        let r = QPoint3 { x: self.xs[ref_idx], y: self.ys[ref_idx], z: self.zs[ref_idx] };
        self.scan_to_into(r, out);
    }

    fn scan_distances_to_into(&mut self, r: &QPoint3, out: &mut Vec<u32>) {
        self.scan_to_into(*r, out);
    }

    fn reset(&mut self) {
        self.xs.clear();
        self.ys.clear();
        self.zs.clear();
        self.cycles = 0;
        self.ledger = EnergyLedger::new();
    }

    fn cycles(&self) -> u64 {
        self.cycles
    }

    fn ledger(&self) -> &EnergyLedger {
        &self.ledger
    }

    fn supports_partition_pruning(&self) -> bool {
        true
    }
}

/// Median-partition pruned preprocessing kernels — the Fast tier's FPS,
/// lattice query and kNN rewritten against a [`MedianIndex`].
///
/// Exactness argument (why pruning is byte-identical, not approximate):
///
/// - **FPS min-update**: the kernel keeps the full temporary-distance
///   array `live` (permutation order) plus each cell's running maximum.
///   After sampling centroid `c`, a cell may be skipped iff
///   `lb(c, cell) >= cellmax`: every member's distance to `c` is then
///   `>= lb >= cellmax >= live[i]`, so `min(live[i], d) = live[i]` for
///   the whole cell — no value can change. Skipped cells keep exact TDs.
/// - **FPS max-select**: the arg-max over exact TDs is found from the
///   per-cell maxima, then resolved to the *lowest original index*
///   attaining it — the CAM's lowest-matchline priority.
/// - **Lattice query**: a cell is skipped iff `lb(centroid, cell) >`
///   the grid range — no member can be in range. Surviving hits are
///   sorted back into original-index order before streaming into the
///   [`TopKSorter`], so the sorter's order-dependent cycle/energy
///   accounting is reproduced exactly, not just its output.
/// - **kNN**: branch-and-bound in original-index order. A cell may drop
///   out of distance computation iff the sorter pipeline is saturated
///   and `lb(query, cell) >` the current k-th best distance — every
///   member's `(distance, index)` then compares strictly greater than
///   the k-th best entry, so the engine loop would reject its push. A
///   rejected push on a saturated pipeline costs exactly one cycle and
///   one full comparator pass regardless of the distance value, so runs
///   of proven-rejected members are replayed charge-identically through
///   [`TopKSorter::push_beyond`] without touching their coordinates.
///
/// Accounting: every charge the engine-driven loop makes
/// (`load_tile`/scan/`load_initial`/`update_min`/`invalidate`/searches,
/// and the bit-CAM search energy, which needs one cheap flat pass over
/// the exact TDs) is made here in identical closed form — the ledger and
/// cycle totals folded into [`crate::coordinator::CloudStats`] are
/// byte-identical to both engine tiers. Only host time changes.
pub struct PrunedPreprocessor {
    apd_cfg: ApdCimConfig,
    cam_cfg: CamConfig,
    /// Temporary distances (`D_s`) in index-permutation order.
    live: Vec<u32>,
    /// Running maximum live TD per index cell.
    cellmax: Vec<u32>,
    /// `(original index, distance)` lattice hits of one centroid.
    hits: Vec<(u32, u32)>,
    /// Per-cell bounding-box lower bound of one kNN query.
    cell_lb: Vec<u32>,
    cycles: u64,
    ledger: EnergyLedger,
}

impl PrunedPreprocessor {
    /// Fresh kernels for the given engine geometries (accounting must
    /// price against the same configs the per-operation engines use).
    pub fn new(apd_cfg: ApdCimConfig, cam_cfg: CamConfig) -> Self {
        Self {
            apd_cfg,
            cam_cfg,
            live: Vec::new(),
            cellmax: Vec::new(),
            hits: Vec::new(),
            cell_lb: Vec::new(),
            cycles: 0,
            ledger: EnergyLedger::new(),
        }
    }

    /// Zero the cycle counter and ledger (lane reuse across clouds);
    /// working buffers keep their capacity.
    pub fn reset(&mut self) {
        self.cycles = 0;
        self.ledger = EnergyLedger::new();
    }

    /// Cycle count accumulated so far (APD + CAM + sorter overflow,
    /// summed — the same total the engine-driven loop spreads across
    /// `apd.cycles() + cam.cycles()` + the sorter's stats line).
    pub fn cycles(&self) -> u64 {
        self.cycles
    }

    /// Event ledger accumulated so far.
    pub fn ledger(&self) -> &EnergyLedger {
        &self.ledger
    }

    /// Byte capacities of the growable working buffers (scratch-arena
    /// accounting; order is stable).
    pub fn buffer_bytes(&self) -> [u64; 4] {
        use std::mem::size_of;
        [
            (self.live.capacity() * size_of::<u32>()) as u64,
            (self.cellmax.capacity() * size_of::<u32>()) as u64,
            (self.hits.capacity() * size_of::<(u32, u32)>()) as u64,
            (self.cell_lb.capacity() * size_of::<u32>()) as u64,
        ]
    }

    fn scan_cycles(&self, n: usize) -> u64 {
        n.div_ceil(self.apd_cfg.distances_per_cycle()) as u64
    }

    /// Closed-form charges of one full-array distance scan (reference
    /// readout + one distance op per resident point).
    fn charge_scan(&mut self, n: usize) {
        self.ledger.charge(Event::RegBit, 48);
        self.cycles += 1;
        self.ledger.charge(Event::ApdDistanceOp, n as u64);
        self.cycles += self.scan_cycles(n);
    }

    /// Zero the TD of original index `i` (a sampled centroid drops out)
    /// and restore its cell's running maximum.
    fn invalidate(&mut self, index: &MedianIndex, i: usize) {
        let p = index.pos(i);
        self.live[p] = 0;
        let c = index.cell_of(i);
        let cell = index.cells()[c];
        self.cellmax[c] = self.live[cell.start as usize..cell.end as usize]
            .iter()
            .copied()
            .max()
            .unwrap_or(0);
        self.ledger.charge(Event::CamWriteBit, TD_BITS as u64);
        self.cycles += 1;
    }

    /// Pruned farthest-point sampling over an indexed tile: `m` sampled
    /// original indices land in `idx` (cleared and refilled),
    /// byte-identical to [`crate::coordinator::Pipeline::cam_fps_into`]
    /// driven over either engine tier — indices, cycle total and ledger.
    pub fn fps_into(&mut self, index: &MedianIndex, m: usize, start: usize, idx: &mut Vec<usize>) {
        self.fps_core(index, m, start, None, idx);
    }

    /// Warm-started FPS for frame-coherent streams: identical to
    /// [`Self::fps_into`] in every output, cycle and ledger byte —
    /// `hint` (the previous frame's sample sequence) is **never
    /// trusted**. Each iteration recomputes the true min-TD arg-max
    /// under the same lowest-original-index tie rule (verify), and the
    /// hint entry merely gets credited as a *warm hit* when it matches
    /// (accept); a mismatch simply keeps the recomputed centroid — the
    /// cold path — so correctness never rests on frame coherence.
    /// Returns the warm-hit count (how much of the previous sample set
    /// re-verified), which feeds
    /// `crate::coordinator::CloudStats::fps_warm_hits` and the
    /// BENCH_stream steady-state model.
    pub fn fps_warm_into(
        &mut self,
        index: &MedianIndex,
        m: usize,
        start: usize,
        hint: &[u32],
        idx: &mut Vec<usize>,
    ) -> u64 {
        self.fps_core(index, m, start, Some(hint), idx)
    }

    /// Shared body of [`Self::fps_into`] / [`Self::fps_warm_into`]; the
    /// hint only counts verified re-hits and never steers selection, so
    /// both entry points are one algorithm with one accounting.
    fn fps_core(
        &mut self,
        index: &MedianIndex,
        m: usize,
        start: usize,
        hint: Option<&[u32]>,
        idx: &mut Vec<usize>,
    ) -> u64 {
        let n = index.len();
        assert!(
            n <= self.apd_cfg.capacity(),
            "tile of {n} exceeds APD-CIM capacity {}",
            self.apd_cfg.capacity()
        );
        assert!(n <= self.cam_cfg.capacity(), "tile TDs exceed CAM capacity");
        assert!(m >= 1 && start < n, "cannot sample {m} of {n} from {start}");

        // Tile load into the distance array (SRAM writes, row-parallel).
        self.ledger.charge(Event::SramBit, n as u64 * 48);
        self.cycles += self.scan_cycles(n);
        // Initial scan against the seed point.
        self.charge_scan(n);
        let r0 = index.point(start);
        self.live.clear();
        self.live.resize(n, 0);
        self.cellmax.clear();
        self.cellmax.resize(index.cells().len(), 0);
        for (c, cell) in index.cells().iter().enumerate() {
            let (xs, ys, zs) = index.cell_soa(cell);
            let live = &mut self.live[cell.start as usize..cell.end as usize];
            let mut mx = 0u32;
            l1_soa_lanes(xs, ys, zs, r0, |k, d| {
                live[k] = d;
                mx = mx.max(d);
            });
            self.cellmax[c] = mx;
        }
        // CAM initial-TD load.
        self.ledger.charge(Event::CamWriteBit, n as u64 * TD_BITS as u64 * 2);
        self.cycles += n.div_ceil(self.cam_cfg.n_groups) as u64;
        self.invalidate(index, start);
        idx.clear();
        idx.push(start);

        let mut warm_hits = 0u64;
        for iter in 1..m {
            // --- MAX search: arg-max from the per-cell maxima, lowest
            // original index winning ties (matchline priority). ---
            let best_val = self.cellmax.iter().copied().max().expect("non-empty tile");
            let mut best_orig = usize::MAX;
            for (c, cell) in index.cells().iter().enumerate() {
                if self.cellmax[c] != best_val {
                    continue;
                }
                for p in cell.start as usize..cell.end as usize {
                    if self.live[p] == best_val {
                        best_orig = best_orig.min(index.orig(p));
                    }
                }
            }
            debug_assert!(best_orig != usize::MAX);
            // Analytic bit-search energy over the exact TDs (one cheap
            // flat pass; same formula as FastMaxSearch::max_search).
            let mut searched: u64 = 0;
            for &v in &self.live {
                let xor = v ^ best_val;
                let h = if xor == 0 { 0 } else { 31 - xor.leading_zeros() };
                searched += (TD_BITS - h) as u64;
            }
            self.ledger.charge(Event::CamSearchCell, searched);
            self.cycles += TD_BITS as u64;
            // Data-CAM resolve cycle: every occupied cell participates.
            self.ledger.charge(Event::CamSearchCell, n as u64);
            self.cycles += 1;

            // Verify-then-accept: the recomputed arg-max is always what
            // gets sampled; a matching hint entry only counts as a warm
            // hit (the previous frame's pick re-verified exactly).
            if let Some(h) = hint {
                if h.get(iter).is_some_and(|&p| p as usize == best_orig) {
                    warm_hits += 1;
                }
            }

            idx.push(best_orig);
            self.invalidate(index, best_orig);

            // --- scan + min-update, pruned per cell. ---
            self.charge_scan(n);
            self.ledger.charge(Event::CamComparePair, n as u64);
            self.ledger.charge(Event::CamWriteBit, n as u64 * TD_BITS as u64);
            let r = index.point(best_orig);
            for (c, cell) in index.cells().iter().enumerate() {
                // Exact skip: every member's distance to `r` is >= the
                // box bound >= the cell's max TD, so no TD can shrink.
                if cell.l1_lower_bound(&r) >= self.cellmax[c] {
                    continue;
                }
                let (xs, ys, zs) = index.cell_soa(cell);
                let live = &mut self.live[cell.start as usize..cell.end as usize];
                let mut mx = 0u32;
                l1_soa_lanes(xs, ys, zs, r, |k, d| {
                    let v = live[k].min(d);
                    live[k] = v;
                    mx = mx.max(v);
                });
                self.cellmax[c] = mx;
            }
        }
        warm_hits
    }

    /// Pruned lattice query over an indexed tile: one simulated
    /// full-array scan per centroid, hits gathered only from cells whose
    /// box bound admits the grid range, re-sorted into original-index
    /// order and streamed through the real [`TopKSorter`] — groups, the
    /// sorter's cycle overflow and its ledger are byte-identical to the
    /// engine-driven query.
    pub fn lattice_query_into(
        &mut self,
        index: &MedianIndex,
        centroids: &[usize],
        grid_range: u32,
        k: usize,
        sorter: &mut TopKSorter,
        out: &mut GroupsCsr,
    ) {
        let n = index.len();
        out.clear();
        for &ci in centroids {
            let r = index.point(ci);
            self.charge_scan(n);
            sorter.reset(k);
            self.hits.clear();
            for cell in index.cells() {
                if cell.l1_lower_bound(&r) > grid_range {
                    continue;
                }
                let base = cell.start as usize;
                let (xs, ys, zs) = index.cell_soa(cell);
                let hits = &mut self.hits;
                l1_soa_lanes(xs, ys, zs, r, |kk, d| {
                    if d <= grid_range {
                        hits.push((index.orig(base + kk) as u32, d));
                    }
                });
            }
            // The engine-driven scan streams hits in original-index
            // order; the sorter's energy is order-dependent, so restore
            // that order before pushing.
            self.hits.sort_unstable_by_key(|&(o, _)| o);
            for &(o, d) in &self.hits {
                sorter.push(d, o as usize);
            }
            // Sorter accepts one hit/cycle overlapped with the scan;
            // only the overflow beyond the scan length costs extra
            // (the one shared fold — see TopKSorter::overflow_beyond_scan).
            self.cycles += sorter.overflow_beyond_scan(n, self.apd_cfg.distances_per_cycle());
            self.ledger.merge(sorter.ledger());
            let start = out.indices.len();
            for &(_, j) in sorter.entries() {
                out.indices.push(j);
            }
            crate::sampling::query::pad_and_seal(out, start, k, || nearest_pruned(index, &r));
        }
    }

    /// Partition-pruned kNN over an indexed tile: one simulated
    /// full-array scan per query, then a branch-and-bound replay of the
    /// engine-driven sorter stream
    /// ([`crate::coordinator::Pipeline::cam_knn_into`]) in original-index
    /// order — groups, the sorter's cycle overflow and its ledger are
    /// byte-identical to the engine loop on either tier.
    ///
    /// Candidates stream by original index. Until the pipeline holds `k`
    /// entries every push inserts, so the prefix is replayed verbatim.
    /// Once saturated, a member of a cell whose box bound strictly
    /// exceeds the current k-th best distance is *proven* rejected (its
    /// `(distance, index)` compares greater than the k-th best entry:
    /// its distance is strictly larger, or — on the `lb ==` boundary the
    /// strict skip test refuses — possibly tied, which is why ties are
    /// still computed). Proven-rejected runs are batch-charged through
    /// [`TopKSorter::push_beyond`] without reading coordinates; everything
    /// else goes through a real [`TopKSorter::push`].
    pub fn knn_into(
        &mut self,
        index: &MedianIndex,
        queries: &[QPoint3],
        k: usize,
        sorter: &mut TopKSorter,
        out: &mut GroupsCsr,
    ) {
        let n = index.len();
        assert!(k >= 1 && k <= n, "cannot take {k} nearest of {n}");
        out.clear();
        for q in queries {
            self.charge_scan(n);
            sorter.reset(k);
            self.cell_lb.clear();
            self.cell_lb.extend(index.cells().iter().map(|c| c.l1_lower_bound(q)));
            let mut run = 0u64;
            for i in 0..n {
                if sorter.entries().len() == k {
                    // Saturated: skip iff the member's cell bound proves
                    // the push would fall off the pipeline (`>` strict —
                    // an equal bound can still tie-insert under a higher
                    // resident index).
                    let worst = sorter.entries()[k - 1].0;
                    if self.cell_lb[index.cell_of(i)] > worst {
                        run += 1;
                        continue;
                    }
                    if run > 0 {
                        sorter.push_beyond(run);
                        run = 0;
                    }
                }
                sorter.push(index.point(i).l1(q), i);
            }
            sorter.push_beyond(run);
            self.cycles +=
                sorter.overflow_beyond_scan(n, self.apd_cfg.distances_per_cycle());
            self.ledger.merge(sorter.ledger());
            for &(_, j) in sorter.entries() {
                out.indices.push(j);
            }
            out.seal_group();
        }
    }
}

/// Branch-and-bound nearest point to `r` (L1, lowest original index on
/// ties) — the pruned spelling of the empty-group fallback
/// `(0..n).min_by_key(|&j| dist[j])`.
fn nearest_pruned(index: &MedianIndex, r: &QPoint3) -> usize {
    let mut best_d = u32::MAX;
    let mut best_i = usize::MAX;
    for cell in index.cells() {
        // `>` not `>=`: a cell whose bound ties the best distance may
        // still hold an equal-distance point with a lower index.
        if cell.l1_lower_bound(r) > best_d {
            continue;
        }
        let base = cell.start as usize;
        let (xs, ys, zs) = index.cell_soa(cell);
        l1_soa_lanes(xs, ys, zs, *r, |k, d| {
            let o = index.orig(base + k);
            if d < best_d || (d == best_d && o < best_i) {
                best_d = d;
                best_i = o;
            }
        });
    }
    debug_assert!(best_i != usize::MAX, "non-empty tile");
    best_i
}

/// Fast-tier MAX search: flat live-TD storage, analytic bit-CAM energy.
#[derive(Debug, Clone)]
pub struct FastMaxSearch {
    cfg: CamConfig,
    live: Vec<u32>,
    occupied: Vec<bool>,
    cycles: u64,
    ledger: EnergyLedger,
}

impl FastMaxSearch {
    /// An empty array with the given geometry.
    pub fn new(cfg: CamConfig) -> Self {
        Self {
            cfg,
            live: vec![0; cfg.capacity()],
            occupied: vec![false; cfg.capacity()],
            cycles: 0,
            ledger: EnergyLedger::new(),
        }
    }
}

impl MaxSearchEngine for FastMaxSearch {
    fn capacity(&self) -> usize {
        self.cfg.capacity()
    }

    fn load_initial(&mut self, tds: &[u32]) {
        assert!(tds.len() <= self.cfg.capacity(), "tile TDs exceed CAM capacity");
        self.occupied.iter_mut().for_each(|o| *o = false);
        for (i, &d) in tds.iter().enumerate() {
            debug_assert!(d < (1 << TD_BITS));
            self.live[i] = d;
            self.occupied[i] = true;
        }
        self.ledger.charge(Event::CamWriteBit, tds.len() as u64 * TD_BITS as u64 * 2);
        self.cycles += tds.len().div_ceil(self.cfg.n_groups) as u64;
    }

    fn update_min(&mut self, i: usize, new_distance: u32) {
        debug_assert!(new_distance < (1 << TD_BITS));
        assert!(self.occupied[i], "update of unoccupied TD {i}");
        self.live[i] = self.live[i].min(new_distance);
        self.ledger.charge(Event::CamComparePair, 1);
        self.ledger.charge(Event::CamWriteBit, TD_BITS as u64);
    }

    fn invalidate(&mut self, i: usize) {
        self.live[i] = 0;
        self.ledger.charge(Event::CamWriteBit, TD_BITS as u64);
        self.cycles += 1;
    }

    fn reset(&mut self) {
        self.live.fill(0);
        self.occupied.fill(false);
        self.cycles = 0;
        self.ledger = EnergyLedger::new();
    }

    fn max_search(&mut self) -> (u32, usize) {
        // Max value + lowest winning index in one pass.
        let mut best = 0u32;
        let mut idx = usize::MAX;
        for (i, (&v, &occ)) in self.live.iter().zip(&self.occupied).enumerate() {
            if occ && (idx == usize::MAX || v > best) {
                best = v;
                idx = i;
            }
        }
        assert!(idx != usize::MAX, "bit-CAM value must exist in the array");
        // Analytic bit-search energy: entry `v` is searched once per bit
        // cycle until its prefix first diverges from the max's, i.e.
        // TD_BITS - msb(v ^ max) times (TD_BITS when v == max).
        let mut searched: u64 = 0;
        for (&v, &occ) in self.live.iter().zip(&self.occupied) {
            if occ {
                let xor = v ^ best;
                let h = if xor == 0 { 0 } else { 31 - xor.leading_zeros() };
                searched += (TD_BITS - h) as u64;
            }
        }
        self.ledger.charge(Event::CamSearchCell, searched);
        self.cycles += TD_BITS as u64;
        // Data-CAM resolve cycle: every occupied cell participates once.
        self.ledger.charge(Event::CamSearchCell, self.occupied() as u64);
        self.cycles += 1;
        (best, idx)
    }

    fn live_td(&self, i: usize) -> u32 {
        self.live[i]
    }

    fn occupied(&self) -> usize {
        self.occupied.iter().filter(|&&o| o).count()
    }

    fn cycles(&self) -> u64 {
        self.cycles
    }

    fn ledger(&self) -> &EnergyLedger {
        &self.ledger
    }
}

/// Fast-tier MAC engine: native 64-bit dot products, SC-CIM cost model.
#[derive(Debug, Clone)]
pub struct FastMac {
    cfg: ScCimConfig,
    cycles: u64,
    ledger: EnergyLedger,
}

impl FastMac {
    /// A fresh engine with zeroed counters.
    pub fn new(cfg: ScCimConfig) -> Self {
        Self { cfg, cycles: 0, ledger: EnergyLedger::new() }
    }
}

impl MacEngine for FastMac {
    fn dot(&mut self, x: &[u16], w: &[i16]) -> i64 {
        assert_eq!(x.len(), w.len());
        let acc: i64 = x.iter().zip(w).map(|(&a, &b)| a as i64 * b as i64).sum();
        self.cycles += 4;
        self.ledger.charge(Event::MacSc, x.len() as u64);
        acc
    }

    fn matmul_cost(&mut self, n: usize, k: usize, m: usize) -> u64 {
        let macs = (n as u64) * (k as u64) * (m as u64);
        self.ledger.charge(Event::MacSc, macs);
        let waves = macs.div_ceil(self.cfg.parallel_macs());
        let cycles = waves * 4;
        self.cycles += cycles;
        cycles
    }

    fn reset(&mut self) {
        self.cycles = 0;
        self.ledger = EnergyLedger::new();
    }

    fn cycles(&self) -> u64 {
        self.cycles
    }

    fn ledger(&self) -> &EnergyLedger {
        &self.ledger
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cim::apd_cim::ApdCim;
    use crate::cim::max_cam::CamArray;
    use crate::cim::sc_cim::ScCim;
    use crate::pointcloud::synthetic::make_class_cloud;
    use crate::quant::quantize_cloud;
    use crate::rng::Rng64;

    fn tile(n: usize, seed: u64) -> Vec<QPoint3> {
        quantize_cloud(&make_class_cloud(2, n, seed))
    }

    #[test]
    fn distance_scan_matches_bit_exact() {
        let t = tile(777, 5);
        let mut gate = ApdCim::new(ApdCimConfig::default());
        let mut fast = FastDistance::new(ApdCimConfig::default());
        DistanceEngine::load_tile(&mut gate, &t);
        fast.load_tile(&t);
        for start in [0usize, 3, 776] {
            let a = DistanceEngine::scan_distances(&mut gate, start);
            let b = fast.scan_distances(start);
            assert_eq!(a, b);
        }
        assert_eq!(DistanceEngine::cycles(&gate), fast.cycles());
        assert_eq!(DistanceEngine::ledger(&gate), fast.ledger());
    }

    #[test]
    fn max_search_energy_formula_matches_gate_walk() {
        let mut rng = Rng64::new(77);
        for n in [1usize, 7, 130, 2048] {
            let tds: Vec<u32> =
                (0..n).map(|_| rng.below(1u64 << TD_BITS) as u32).collect();
            let mut gate = CamArray::new(CamConfig::default());
            let mut fast = FastMaxSearch::new(CamConfig::default());
            MaxSearchEngine::load_initial(&mut gate, &tds);
            fast.load_initial(&tds);
            let a = gate.bit_cam_max();
            let b = fast.max_search();
            assert_eq!(a, b, "n={n}");
            assert_eq!(MaxSearchEngine::cycles(&gate), fast.cycles(), "n={n}");
            assert_eq!(MaxSearchEngine::ledger(&gate), fast.ledger(), "n={n}");
        }
    }

    #[test]
    fn min_update_and_invalidate_match() {
        let mut gate = CamArray::new(CamConfig::default());
        let mut fast = FastMaxSearch::new(CamConfig::default());
        MaxSearchEngine::load_initial(&mut gate, &[500, 100, 300]);
        fast.load_initial(&[500, 100, 300]);
        for (i, d) in [(0usize, 200u32), (1, 400), (2, 300), (0, 10)] {
            MaxSearchEngine::update_min(&mut gate, i, d);
            fast.update_min(i, d);
        }
        MaxSearchEngine::invalidate(&mut gate, 1);
        fast.invalidate(1);
        for i in 0..3 {
            assert_eq!(MaxSearchEngine::live_td(&gate, i), fast.live_td(i));
        }
        assert_eq!(MaxSearchEngine::ledger(&gate), fast.ledger());
        assert_eq!(gate.bit_cam_max(), fast.max_search());
    }

    #[test]
    fn pruned_fps_matches_engine_loop() {
        for (n, seed) in [(65usize, 21u64), (777, 5), (1024, 9), (2048, 13)] {
            let t = tile(n, seed);
            let m = (n / 4).max(2);
            // Reference: the engine-driven loop on the fast tier.
            let mut apd = FastDistance::new(ApdCimConfig::default());
            let mut cam = FastMaxSearch::new(CamConfig::default());
            apd.load_tile(&t);
            let want_idx = crate::coordinator::Pipeline::cam_fps(&mut apd, &mut cam, m, 0);
            // Pruned kernels over the median index.
            let mut index = MedianIndex::new();
            index.build(&t);
            let mut pp = PrunedPreprocessor::new(ApdCimConfig::default(), CamConfig::default());
            let mut idx = Vec::new();
            pp.fps_into(&index, m, 0, &mut idx);
            assert_eq!(idx, want_idx, "n={n}");
            let mut want_ledger = EnergyLedger::new();
            want_ledger.merge(DistanceEngine::ledger(&apd));
            want_ledger.merge(MaxSearchEngine::ledger(&cam));
            assert_eq!(pp.ledger(), &want_ledger, "n={n} ledger");
            assert_eq!(
                pp.cycles(),
                DistanceEngine::cycles(&apd) + MaxSearchEngine::cycles(&cam),
                "n={n} cycles"
            );
        }
    }

    #[test]
    fn pruned_fps_handles_duplicate_points() {
        // Duplicates force distance ties (and an all-zero TD endgame when
        // m exhausts the distinct points) — the tie-break and the
        // degenerate lowest-index behaviour must match the engine loop.
        let mut t = tile(16, 3);
        for i in 8..16 {
            t[i] = t[i - 8];
        }
        let mut apd = FastDistance::new(ApdCimConfig::default());
        let mut cam = FastMaxSearch::new(CamConfig::default());
        apd.load_tile(&t);
        let want_idx = crate::coordinator::Pipeline::cam_fps(&mut apd, &mut cam, 16, 0);
        let mut index = MedianIndex::new();
        index.build(&t);
        let mut pp = PrunedPreprocessor::new(ApdCimConfig::default(), CamConfig::default());
        let mut idx = Vec::new();
        pp.fps_into(&index, 16, 0, &mut idx);
        assert_eq!(idx, want_idx);
        let mut want_ledger = EnergyLedger::new();
        want_ledger.merge(DistanceEngine::ledger(&apd));
        want_ledger.merge(MaxSearchEngine::ledger(&cam));
        assert_eq!(pp.ledger(), &want_ledger);
    }

    #[test]
    fn warm_fps_verifies_hint_and_never_diverges() {
        let t = tile(512, 17);
        let mut index = MedianIndex::new();
        index.build(&t);
        let m = 128usize;
        let mut cold = PrunedPreprocessor::new(ApdCimConfig::default(), CamConfig::default());
        let mut cold_idx = Vec::new();
        cold.fps_into(&index, m, 0, &mut cold_idx);
        // A perfect hint (the cold result itself) re-verifies fully...
        let hint: Vec<u32> = cold_idx.iter().map(|&i| i as u32).collect();
        let mut warm = PrunedPreprocessor::new(ApdCimConfig::default(), CamConfig::default());
        let mut warm_idx = Vec::new();
        let hits = warm.fps_warm_into(&index, m, 0, &hint, &mut warm_idx);
        assert_eq!(hits, (m - 1) as u64, "perfect hint must re-verify every pick");
        // ...and the warm path is byte-identical to cold: outputs,
        // cycles, ledger.
        assert_eq!(warm_idx, cold_idx);
        assert_eq!(warm.cycles(), cold.cycles());
        assert_eq!(warm.ledger(), cold.ledger());
        // A garbage hint changes nothing but the hit count — including
        // an empty and a wrong-length hint.
        for bad in [vec![], vec![9999u32; 3], hint.iter().map(|&p| p ^ 1).collect()] {
            let mut pp = PrunedPreprocessor::new(ApdCimConfig::default(), CamConfig::default());
            let mut idx = Vec::new();
            let h = pp.fps_warm_into(&index, m, 0, &bad, &mut idx);
            assert_eq!(idx, cold_idx, "hint steered selection");
            assert_eq!(pp.cycles(), cold.cycles());
            assert_eq!(pp.ledger(), cold.ledger());
            assert!(h < (m - 1) as u64, "bad hint cannot fully re-verify");
        }
    }

    #[test]
    fn pruned_lattice_matches_full_scan_reference() {
        let n = 1024usize;
        let t = tile(n, 33);
        let centroids = vec![0usize, 5, 17, 999];
        let (k, grid_range) = (32usize, crate::quant::radius_to_grid(1.6 * 0.2));
        let mut index = MedianIndex::new();
        index.build(&t);
        let mut pp = PrunedPreprocessor::new(ApdCimConfig::default(), CamConfig::default());
        let mut sorter = TopKSorter::new(1);
        let mut out = GroupsCsr::new();
        pp.lattice_query_into(&index, &centroids, grid_range, k, &mut sorter, &mut out);
        // Reference: full scans + the same sorter/padding convention.
        let mut apd = FastDistance::new(ApdCimConfig::default());
        apd.load_tile(&t);
        let mut ref_sorter = TopKSorter::new(1);
        let mut ref_out = GroupsCsr::new();
        let mut dist = Vec::new();
        let mut want_cycles = 0u64;
        let mut want_ledger = EnergyLedger::new();
        for &ci in &centroids {
            apd.scan_distances_into(ci, &mut dist);
            ref_sorter.reset(k);
            for (j, &dj) in dist.iter().enumerate() {
                if dj <= grid_range {
                    ref_sorter.push(dj, j);
                }
            }
            want_cycles += ref_sorter
                .overflow_beyond_scan(dist.len(), ApdCimConfig::default().distances_per_cycle());
            want_ledger.merge(ref_sorter.ledger());
            let start = ref_out.indices.len();
            for &(_, j) in ref_sorter.entries() {
                ref_out.indices.push(j);
            }
            crate::sampling::query::pad_and_seal(&mut ref_out, start, k, || {
                (0..dist.len()).min_by_key(|&j| dist[j]).unwrap()
            });
        }
        assert_eq!(out, ref_out, "groups");
        // The pruned kernel charges the scans itself (the reference
        // engine charged them into `apd`, minus its tile load).
        let scans = centroids.len() as u64;
        want_cycles += scans * (1 + n.div_ceil(16) as u64);
        want_ledger.charge(Event::RegBit, 48 * scans);
        want_ledger.charge(Event::ApdDistanceOp, n as u64 * scans);
        assert_eq!(pp.cycles(), want_cycles, "cycles");
        assert_eq!(pp.ledger(), &want_ledger, "ledger");
    }

    /// Engine-loop kNN reference on a fast-tier APD, returning everything
    /// the pruned kernel must reproduce (groups) plus the loop's own
    /// accounting for the charge-identity asserts.
    fn knn_engine_reference(
        t: &[QPoint3],
        queries: &[QPoint3],
        k: usize,
    ) -> (GroupsCsr, u64, EnergyLedger) {
        let mut apd = FastDistance::new(ApdCimConfig::default());
        apd.load_tile(t);
        let mut sorter = TopKSorter::new(1);
        let mut dist = Vec::new();
        let mut out = GroupsCsr::new();
        let mut stats = crate::coordinator::CloudStats::default();
        crate::coordinator::Pipeline::cam_knn_into(
            &mut apd,
            queries,
            k,
            &mut sorter,
            &mut dist,
            &mut out,
            &mut stats,
        );
        let mut ledger = EnergyLedger::new();
        ledger.merge(DistanceEngine::ledger(&apd));
        ledger.merge(&stats.ledger);
        (out, DistanceEngine::cycles(&apd) + stats.preproc_cycles, ledger)
    }

    fn assert_pruned_knn_matches(t: &[QPoint3], queries: &[QPoint3], k: usize, tag: &str) {
        let n = t.len();
        let (want_out, want_cycles, want_ledger) = knn_engine_reference(t, queries, k);
        let mut index = MedianIndex::new();
        index.build(t);
        let mut pp = PrunedPreprocessor::new(ApdCimConfig::default(), CamConfig::default());
        let mut sorter = TopKSorter::new(1);
        let mut out = GroupsCsr::new();
        pp.knn_into(&index, queries, k, &mut sorter, &mut out);
        assert_eq!(out, want_out, "{tag}: groups");
        // The engine side charged its tile load (SRAM writes + load
        // cycles); the pruned kernel assumes a loaded array, like the
        // lattice query. Add the load to the pruned side and demand
        // byte-identity.
        let mut got_ledger = EnergyLedger::new();
        got_ledger.merge(pp.ledger());
        got_ledger.charge(Event::SramBit, n as u64 * 48);
        assert_eq!(got_ledger, want_ledger, "{tag}: ledger");
        let load_cycles = n.div_ceil(ApdCimConfig::default().distances_per_cycle()) as u64;
        assert_eq!(pp.cycles() + load_cycles, want_cycles, "{tag}: cycles");
    }

    #[test]
    fn pruned_knn_matches_engine_loop() {
        for (n, seed) in [(65usize, 21u64), (777, 5), (2048, 13)] {
            let t = tile(n, seed);
            // Resident points and off-tile queries alike.
            let mut queries: Vec<QPoint3> = (0..8).map(|i| t[(i * 97) % n]).collect();
            queries.push(QPoint3 { x: 0, y: 0, z: 0 });
            queries.push(QPoint3 { x: u16::MAX, y: 12_000, z: 40_000 });
            for k in [1usize, 16, n.min(63)] {
                assert_pruned_knn_matches(&t, &queries, k, &format!("n={n} k={k}"));
            }
        }
    }

    #[test]
    fn pruned_knn_handles_duplicates_and_all_ties() {
        // Duplicate points force exact (distance, index) tie chains
        // through the sorter; all-identical tiles degenerate every
        // distance to a single value, so the k lowest indices must win
        // and no cell may ever be skipped incorrectly.
        let mut dup = tile(64, 3);
        for i in 16..64 {
            dup[i] = dup[i % 16];
        }
        let queries: Vec<QPoint3> = dup[..6].to_vec();
        for k in [1usize, 20, 64] {
            assert_pruned_knn_matches(&dup, &queries, k, &format!("dup k={k}"));
        }
        let same = vec![QPoint3 { x: 100, y: 200, z: 300 }; 40];
        let far = vec![QPoint3 { x: 100, y: 200, z: 300 }, QPoint3 { x: 0, y: 0, z: 0 }];
        assert_pruned_knn_matches(&same, &far, 7, "all-ties");
    }

    #[test]
    fn pruned_nearest_matches_linear_scan() {
        let t = tile(333, 44);
        let mut index = MedianIndex::new();
        index.build(&t);
        for r in [t[0], t[200], QPoint3 { x: 0, y: u16::MAX, z: 1000 }] {
            let want = (0..t.len())
                .min_by_key(|&j| t[j].l1(&r))
                .unwrap();
            assert_eq!(nearest_pruned(&index, &r), want);
        }
    }

    #[test]
    fn mac_dot_and_matmul_match() {
        let mut rng = Rng64::new(9);
        let mut gate = ScCim::new(ScCimConfig::default());
        let mut fast = FastMac::new(ScCimConfig::default());
        for len in [1usize, 4, 33] {
            let x: Vec<u16> = (0..len).map(|_| rng.next_u64() as u16).collect();
            let w: Vec<i16> = (0..len).map(|_| rng.next_u64() as i16).collect();
            assert_eq!(MacEngine::dot(&mut gate, &x, &w), fast.dot(&x, &w));
        }
        assert_eq!(
            MacEngine::matmul_cost(&mut gate, 64, 131, 128),
            fast.matmul_cost(64, 131, 128)
        );
        assert_eq!(MacEngine::cycles(&gate), fast.cycles());
        assert_eq!(MacEngine::ledger(&gate), fast.ledger());
    }
}
