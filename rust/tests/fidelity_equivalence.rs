//! The tier boundary contract: the `Fast` engines must be bit-identical
//! to the `BitExact` gate-level models — same outputs, same cycle
//! counts, same energy-ledger event counts — on Table-I-scale workloads,
//! and the pipeline/serving layers built on them must produce identical
//! logits and stats digests for every fidelity tier and worker count.
//! Only host wall-clock time may differ between tiers.

use pc2im::cim::apd_cim::ApdCimConfig;
use pc2im::cim::max_cam::CamConfig;
use pc2im::cim::sc_cim::ScCimConfig;
use pc2im::cim::TopKSorter;
use pc2im::config::{HardwareConfig, PipelineConfig, ServeConfig};
use pc2im::coordinator::serve::stats_digest;
use pc2im::coordinator::{CloudStats, Pipeline, PipelineBuilder};
use pc2im::energy::{EnergyLedger, Event};
use pc2im::engine::fast::PrunedPreprocessor;
use pc2im::engine::{
    distance_engine, mac_engine, max_search_engine, DistanceEngine, Fidelity, MaxSearchEngine,
};
use pc2im::pointcloud::synthetic::{make_labelled_batch, make_workload_cloud, DatasetScale};
use pc2im::quant::{quantize_cloud, QPoint3, TD_BITS};
use pc2im::rng::Rng64;
use pc2im::sampling::{msp_partition, GroupsCsr, MedianIndex};

fn hermetic_cfg(fidelity: Fidelity) -> PipelineConfig {
    PipelineConfig {
        artifacts_dir: std::env::temp_dir()
            .join("pc2im-fidelity-no-artifacts")
            .to_string_lossy()
            .into_owned(),
        fidelity,
        ..PipelineConfig::default()
    }
}

fn assert_engines_agree(
    a: &dyn DistanceEngine,
    b: &dyn DistanceEngine,
    cam_a: &dyn MaxSearchEngine,
    cam_b: &dyn MaxSearchEngine,
    ctx: &str,
) {
    assert_eq!(a.cycles(), b.cycles(), "{ctx}: distance-engine cycles");
    assert_eq!(a.ledger(), b.ledger(), "{ctx}: distance-engine ledger");
    assert_eq!(cam_a.cycles(), cam_b.cycles(), "{ctx}: max-search cycles");
    assert_eq!(cam_a.ledger(), cam_b.ledger(), "{ctx}: max-search ledger");
}

/// Drive the full FPS loop (the paper's Fig. 10(b) flow) on both tiers
/// over one tile and demand identical samples, cycles and ledgers.
fn check_tile(tile: &[QPoint3], m: usize, ctx: &str) {
    let mut apd_bx = distance_engine(Fidelity::BitExact, ApdCimConfig::default());
    let mut apd_fa = distance_engine(Fidelity::Fast, ApdCimConfig::default());
    apd_bx.load_tile(tile);
    apd_fa.load_tile(tile);
    let mut cam_bx = max_search_engine(Fidelity::BitExact, CamConfig::default());
    let mut cam_fa = max_search_engine(Fidelity::Fast, CamConfig::default());

    let idx_bx = Pipeline::cam_fps(apd_bx.as_mut(), cam_bx.as_mut(), m, 0);
    let idx_fa = Pipeline::cam_fps(apd_fa.as_mut(), cam_fa.as_mut(), m, 0);
    assert_eq!(idx_bx, idx_fa, "{ctx}: FPS samples");
    assert_engines_agree(apd_bx.as_ref(), apd_fa.as_ref(), cam_bx.as_ref(), cam_fa.as_ref(), ctx);

    // A lattice-style scan against an arbitrary (cross-tile) reference.
    let r = tile[tile.len() / 2];
    assert_eq!(
        apd_bx.scan_distances_to(&r),
        apd_fa.scan_distances_to(&r),
        "{ctx}: cross-tile scan"
    );
    assert_eq!(apd_bx.cycles(), apd_fa.cycles(), "{ctx}: post-scan cycles");
    assert_eq!(apd_bx.ledger(), apd_fa.ledger(), "{ctx}: post-scan ledger");
}

#[test]
fn engines_bit_identical_across_table1_scales() {
    for scale in DatasetScale::ALL {
        let cloud = make_workload_cloud(scale, 17);
        let q = quantize_cloud(&cloud);
        let tiles = msp_partition(&cloud, ApdCimConfig::default().capacity());
        // Two tiles per scale keep the gate-level walk affordable while
        // still covering every Table-I point distribution.
        for (t, tile) in tiles.iter().take(2).enumerate() {
            let pts: Vec<QPoint3> = tile.indices.iter().map(|&i| q[i]).collect();
            let m = 64.min(pts.len());
            check_tile(&pts, m, &format!("{scale:?} tile {t}"));
        }
    }
}

/// The pruned kernels against the *gate-level* tier, tile by tile across
/// every Table-I point distribution: identical FPS samples and identical
/// total cycle/ledger accounting (the pruned kernels fold the APD + CAM
/// charges into one accumulator; the gate engines keep them separate —
/// the sums must match exactly).
#[test]
fn pruned_kernels_bit_identical_to_gate_level_across_table1_scales() {
    for scale in DatasetScale::ALL {
        let cloud = make_workload_cloud(scale, 23);
        let q = quantize_cloud(&cloud);
        let tiles = msp_partition(&cloud, ApdCimConfig::default().capacity());
        for (t, tile) in tiles.iter().take(2).enumerate() {
            let ctx = format!("{scale:?} tile {t}");
            let pts: Vec<QPoint3> = tile.indices.iter().map(|&i| q[i]).collect();
            let m = 64.min(pts.len());
            let mut apd = distance_engine(Fidelity::BitExact, ApdCimConfig::default());
            let mut cam = max_search_engine(Fidelity::BitExact, CamConfig::default());
            apd.load_tile(&pts);
            let want_idx = Pipeline::cam_fps(apd.as_mut(), cam.as_mut(), m, 0);

            let mut index = MedianIndex::new();
            index.build(&pts);
            let mut pp = PrunedPreprocessor::new(ApdCimConfig::default(), CamConfig::default());
            let mut idx = Vec::new();
            pp.fps_into(&index, m, 0, &mut idx);
            assert_eq!(idx, want_idx, "{ctx}: FPS samples");
            let mut want_ledger = EnergyLedger::new();
            want_ledger.merge(apd.ledger());
            want_ledger.merge(cam.ledger());
            assert_eq!(pp.ledger(), &want_ledger, "{ctx}: ledger");
            assert_eq!(pp.cycles(), apd.cycles() + cam.cycles(), "{ctx}: cycles");
        }
    }
}

/// Drive one kNN workload through all three execution strategies — the
/// gate-level engine loop, the Fast full-scan engine loop, and the
/// partition-pruned branch-and-bound replay — and demand identical CSR
/// groups and identical total cycle/ledger accounting. The pruned
/// kernel skips whole cells with batched `push_beyond` charging, so its
/// fold must land on the exact per-push numbers the engine loops
/// accumulate.
fn knn_three_way(pts: &[QPoint3], queries: &[QPoint3], k: usize, ctx: &str) {
    let mut want: Option<(GroupsCsr, u64, EnergyLedger)> = None;
    for fidelity in Fidelity::ALL {
        let mut apd = distance_engine(fidelity, ApdCimConfig::default());
        apd.load_tile(pts);
        let mut sorter = TopKSorter::new(1);
        let mut dist = Vec::new();
        let mut out = GroupsCsr::new();
        let mut stats = CloudStats::default();
        Pipeline::cam_knn_into(apd.as_mut(), queries, k, &mut sorter, &mut dist, &mut out, &mut stats);
        let mut ledger = EnergyLedger::new();
        ledger.merge(apd.ledger());
        ledger.merge(&stats.ledger);
        let cycles = apd.cycles() + stats.preproc_cycles;
        match &want {
            None => want = Some((out, cycles, ledger)),
            Some((w_out, w_cycles, w_ledger)) => {
                assert_eq!(&out, w_out, "{ctx}: groups ({fidelity})");
                assert_eq!(cycles, *w_cycles, "{ctx}: cycles ({fidelity})");
                assert_eq!(&ledger, w_ledger, "{ctx}: ledger ({fidelity})");
            }
        }
    }
    let (want_out, want_cycles, want_ledger) = want.expect("at least one tier ran");

    let mut index = MedianIndex::new();
    index.build(pts);
    let mut pp = PrunedPreprocessor::new(ApdCimConfig::default(), CamConfig::default());
    let mut sorter = TopKSorter::new(1);
    let mut out = GroupsCsr::new();
    pp.knn_into(&index, queries, k, &mut sorter, &mut out);
    assert_eq!(out, want_out, "{ctx}: groups (pruned)");
    // The engine loops charged their tile load (SRAM writes + load
    // cycles); the pruned kernel assumes a loaded array. Fold the load
    // onto the pruned side and demand byte-identity.
    let mut got_ledger = EnergyLedger::new();
    got_ledger.merge(pp.ledger());
    got_ledger.charge(Event::SramBit, pts.len() as u64 * 48);
    assert_eq!(got_ledger, want_ledger, "{ctx}: ledger (pruned)");
    let load = pts.len().div_ceil(ApdCimConfig::default().distances_per_cycle()) as u64;
    assert_eq!(pp.cycles() + load, want_cycles, "{ctx}: cycles (pruned)");
}

#[test]
fn knn_bit_identical_across_tiers_and_pruning_on_table1_scales() {
    for scale in DatasetScale::ALL {
        let cloud = make_workload_cloud(scale, 41);
        let q = quantize_cloud(&cloud);
        let tiles = msp_partition(&cloud, ApdCimConfig::default().capacity());
        for (t, tile) in tiles.iter().take(2).enumerate() {
            let pts: Vec<QPoint3> = tile.indices.iter().map(|&i| q[i]).collect();
            // Resident and cross-tile queries alike.
            let mut queries: Vec<QPoint3> =
                (0..6).map(|i| pts[(i * 131) % pts.len()]).collect();
            queries.push(QPoint3 { x: 0, y: 0, z: 0 });
            queries.push(QPoint3 { x: u16::MAX, y: 9_000, z: 50_000 });
            let k = 16.min(pts.len());
            knn_three_way(&pts, &queries, k, &format!("{scale:?} tile {t}"));
        }
    }
}

#[test]
fn knn_endgames_bit_identical_across_tiers_and_pruning() {
    // Duplicate-heavy and all-identical tiles: distances tie constantly,
    // so the (distance, index) rule decides everything and no cell may
    // be pruned incorrectly.
    let mut rng = Rng64::new(99);
    let mut dup: Vec<QPoint3> = (0..48)
        .map(|_| QPoint3 {
            x: rng.below(1u64 << 16) as u16,
            y: rng.below(1u64 << 16) as u16,
            z: rng.below(1u64 << 16) as u16,
        })
        .collect();
    for i in 12..48 {
        dup[i] = dup[i % 12];
    }
    let mut queries: Vec<QPoint3> = dup[..5].to_vec();
    queries.push(QPoint3 { x: 0, y: 0, z: 0 });
    for k in [1usize, 13, 48] {
        knn_three_way(&dup, &queries, k, &format!("dup k={k}"));
    }

    let same = vec![QPoint3 { x: 7, y: 7, z: 7 }; 33];
    let far = vec![QPoint3 { x: 7, y: 7, z: 7 }, QPoint3 { x: 60_000, y: 1, z: 2 }];
    for k in [5usize, 33] {
        knn_three_way(&same, &far, k, &format!("all-ties k={k}"));
    }
}

#[test]
fn max_search_bit_identical_on_adversarial_patterns() {
    // Random updates/invalidates interleaved with searches, plus the
    // degenerate all-zero and single-entry patterns.
    let mut rng = Rng64::new(2024);
    for n in [1usize, 3, 129, 2048] {
        let tds: Vec<u32> = (0..n).map(|_| rng.below(1u64 << TD_BITS) as u32).collect();
        let mut bx = max_search_engine(Fidelity::BitExact, CamConfig::default());
        let mut fa = max_search_engine(Fidelity::Fast, CamConfig::default());
        bx.load_initial(&tds);
        fa.load_initial(&tds);
        for round in 0..8 {
            let (va, ia) = bx.max_search();
            let (vb, ib) = fa.max_search();
            assert_eq!((va, ia), (vb, ib), "n={n} round={round}");
            bx.invalidate(ia);
            fa.invalidate(ib);
            for j in 0..n {
                let d = rng.below(1u64 << TD_BITS) as u32;
                bx.update_min(j, d);
                fa.update_min(j, d);
            }
        }
        // all-zero endgame: every TD invalidated
        for j in 0..n {
            bx.invalidate(j);
            fa.invalidate(j);
        }
        assert_eq!(bx.max_search(), fa.max_search(), "n={n} all-zero");
        assert_eq!(bx.cycles(), fa.cycles(), "n={n} cycles");
        assert_eq!(bx.ledger(), fa.ledger(), "n={n} ledger");
        assert_eq!(bx.occupied(), fa.occupied(), "n={n} occupancy");
    }
}

#[test]
fn mac_engine_bit_identical_on_model_matmuls() {
    let mut rng = Rng64::new(7);
    let mut bx = mac_engine(Fidelity::BitExact, ScCimConfig::default());
    let mut fa = mac_engine(Fidelity::Fast, ScCimConfig::default());
    for len in [1usize, 2, 16, 131, 515] {
        let x: Vec<u16> = (0..len).map(|_| rng.next_u64() as u16).collect();
        let w: Vec<i16> = (0..len).map(|_| rng.next_u64() as i16).collect();
        assert_eq!(bx.dot(&x, &w), fa.dot(&x, &w), "dot len={len}");
    }
    // The PointNet2(c) matmul schedule the pipeline prices per cloud.
    for (n, k, m) in [
        (256 * 32, 3, 64),
        (256 * 32, 64, 64),
        (256 * 32, 64, 128),
        (64 * 16, 131, 128),
        (64, 259, 256),
        (1, 512, 256),
        (1, 128, 8),
    ] {
        assert_eq!(bx.matmul_cost(n, k, m), fa.matmul_cost(n, k, m), "matmul {n}x{k}x{m}");
    }
    assert_eq!(bx.cycles(), fa.cycles());
    assert_eq!(bx.ledger(), fa.ledger());
}

#[test]
fn classify_bit_identical_between_tiers() {
    let mut bx = PipelineBuilder::from_config(hermetic_cfg(Fidelity::BitExact)).build().unwrap();
    let mut fa = PipelineBuilder::from_config(hermetic_cfg(Fidelity::Fast)).build().unwrap();
    let (clouds, _) = make_labelled_batch(4, 1024, 31);
    for (i, cloud) in clouds.iter().enumerate() {
        let a = bx.classify(cloud).unwrap();
        let b = fa.classify(cloud).unwrap();
        assert_eq!(a.logits, b.logits, "cloud {i} logits");
        assert_eq!(a.pred, b.pred, "cloud {i} pred");
        assert_eq!(a.stats.preproc_cycles, b.stats.preproc_cycles, "cloud {i} preproc");
        assert_eq!(a.stats.feature_cycles, b.stats.feature_cycles, "cloud {i} feature");
        assert_eq!(a.stats.ledger, b.stats.ledger, "cloud {i} ledger");
    }
}

/// The pruning axis at pipeline level: Fast+pruned (the default),
/// Fast+full-scan and the gate-level tier must classify bit-identically
/// — logits, cycles, ledgers — and the preprocessing-only probe must
/// charge the same accounting on all three.
#[test]
fn pruned_pipeline_bit_identical_to_full_scan_and_gate_level() {
    let mut gate = PipelineBuilder::from_config(hermetic_cfg(Fidelity::BitExact)).build().unwrap();
    let mut full = PipelineBuilder::from_config(hermetic_cfg(Fidelity::Fast))
        .prune(false)
        .build()
        .unwrap();
    let mut pruned = PipelineBuilder::from_config(hermetic_cfg(Fidelity::Fast))
        .prune(true)
        .build()
        .unwrap();
    assert!(gate.config().prune, "prune flag defaults on (gate tier ignores it)");
    let (clouds, _) = make_labelled_batch(3, 1024, 77);
    for (i, cloud) in clouds.iter().enumerate() {
        let a = gate.classify(cloud).unwrap();
        let b = full.classify(cloud).unwrap();
        let c = pruned.classify(cloud).unwrap();
        assert_eq!(a.logits, c.logits, "cloud {i} logits (gate vs pruned)");
        assert_eq!(b.logits, c.logits, "cloud {i} logits (full vs pruned)");
        assert_eq!(a.pred, c.pred, "cloud {i} pred");
        assert_eq!(a.stats.preproc_cycles, c.stats.preproc_cycles, "cloud {i} preproc");
        assert_eq!(b.stats.preproc_cycles, c.stats.preproc_cycles, "cloud {i} preproc full");
        assert_eq!(a.stats.feature_cycles, c.stats.feature_cycles, "cloud {i} feature");
        assert_eq!(a.stats.ledger, c.stats.ledger, "cloud {i} ledger (gate vs pruned)");
        assert_eq!(b.stats.ledger, c.stats.ledger, "cloud {i} ledger (full vs pruned)");

        let pa = gate.preprocess(cloud).unwrap();
        let pc = pruned.preprocess(cloud).unwrap();
        assert_eq!(pa.preproc_cycles, pc.preproc_cycles, "cloud {i} probe cycles");
        assert_eq!(pa.ledger, pc.ledger, "cloud {i} probe ledger");
    }
}

#[test]
fn serve_digest_invariant_across_tiers_and_worker_counts() {
    let hw = HardwareConfig::default();
    let (clouds, labels) = make_labelled_batch(6, 1024, 4100);

    // Reference digest: the bit-exact single-threaded scheduler.
    let mut sched = PipelineBuilder::from_config(hermetic_cfg(Fidelity::BitExact))
        .build_scheduler()
        .unwrap();
    let (_, ref_stats) = sched.classify_batch(&clouds, &labels).unwrap();
    let reference = stats_digest(&ref_stats, &hw);

    for fidelity in Fidelity::ALL {
        for workers in [1usize, 2, 4] {
            let mut engine = PipelineBuilder::from_config(hermetic_cfg(fidelity))
                .build_serve(ServeConfig { workers, queue_depth: 2, ..ServeConfig::default() })
                .unwrap();
            let report = engine.run(&clouds, &labels).unwrap();
            assert_eq!(
                stats_digest(&report.stats, &hw),
                reference,
                "fidelity={fidelity} workers={workers}"
            );
        }
    }
}

#[test]
fn exact_sampling_ablation_is_tier_invariant_too() {
    // The exact-sampling path bypasses the CIM engines for sampling but
    // still prices MACs through the MacEngine — tiers must agree there
    // as well.
    let mut bx = PipelineBuilder::from_config(hermetic_cfg(Fidelity::BitExact))
        .exact_sampling(true)
        .build()
        .unwrap();
    let mut fa = PipelineBuilder::from_config(hermetic_cfg(Fidelity::Fast))
        .exact_sampling(true)
        .build()
        .unwrap();
    let (clouds, _) = make_labelled_batch(2, 1024, 55);
    for cloud in &clouds {
        let a = bx.classify(cloud).unwrap();
        let b = fa.classify(cloud).unwrap();
        assert_eq!(a.logits, b.logits);
        assert_eq!(a.stats.feature_cycles, b.stats.feature_cycles);
        assert_eq!(a.stats.ledger, b.stats.ledger);
    }
}

/// The exact ablation's float FPS + ball query run partition-pruned
/// through the float spatial index by default, on either tier; forcing
/// the full-scan reference loops must not change a single logit, cycle
/// or ledger count. All four (tier, prune) combinations must agree.
#[test]
fn exact_sampling_pruning_is_invariant_across_tiers() {
    let (clouds, _) = make_labelled_batch(2, 1024, 61);
    let mut want: Option<Vec<(Vec<f32>, usize, u64, u64, EnergyLedger)>> = None;
    for fidelity in Fidelity::ALL {
        for prune in [true, false] {
            let mut p = PipelineBuilder::from_config(hermetic_cfg(fidelity))
                .exact_sampling(true)
                .prune(prune)
                .build()
                .unwrap();
            let got: Vec<_> = clouds
                .iter()
                .map(|c| {
                    let r = p.classify(c).unwrap();
                    (
                        r.logits.clone(),
                        r.pred,
                        r.stats.preproc_cycles,
                        r.stats.feature_cycles,
                        r.stats.ledger.clone(),
                    )
                })
                .collect();
            match &want {
                None => want = Some(got),
                Some(w) => {
                    assert!(&got == w, "fidelity={fidelity} prune={prune} diverged");
                }
            }
        }
    }
}
