//! The one way to construct request-path machinery: [`PipelineBuilder`].
//!
//! Every consumer — `pc2im run/eval/serve`, the experiments, the benches,
//! the examples — assembles its [`Pipeline`], [`BatchScheduler`] or
//! [`ServeEngine`] here, so workload options, the hardware model,
//! executor sharing and the engine fidelity tier are wired in exactly one
//! place. Direct `Pipeline` construction is crate-private.
//!
//! ```no_run
//! use pc2im::coordinator::PipelineBuilder;
//! use pc2im::engine::Fidelity;
//!
//! let mut pipeline = PipelineBuilder::new()
//!     .artifacts_dir("artifacts")
//!     .fidelity(Fidelity::Fast)
//!     .build()?;
//! # Ok::<(), anyhow::Error>(())
//! ```

use crate::config::{HardwareConfig, PipelineConfig, ServeConfig};
use crate::coordinator::pipeline::Pipeline;
use crate::coordinator::scheduler::BatchScheduler;
use crate::coordinator::serve::ServeEngine;
use crate::engine::{Dataflow, Fidelity};
use crate::runtime::{Executor, Meta, Runtime};
use anyhow::{Context, Result};
use std::sync::Arc;

/// Builder for [`Pipeline`] and the engines layered on top of it.
///
/// Defaults mirror [`PipelineConfig::default`] and
/// [`HardwareConfig::default`]: the `artifacts` directory, approximate
/// sampling, fp32 artifacts, the bit-exact engine tier.
#[derive(Default)]
pub struct PipelineBuilder {
    cfg: PipelineConfig,
    hw: HardwareConfig,
    shared: Option<(Meta, Arc<dyn Executor>)>,
}

impl PipelineBuilder {
    /// A builder with all defaults.
    pub fn new() -> Self {
        Self::default()
    }

    /// Start from an existing [`PipelineConfig`] (the CLI path).
    pub fn from_config(cfg: PipelineConfig) -> Self {
        Self { cfg, ..Self::default() }
    }

    /// Directory holding `meta.json` and the HLO artifacts.
    pub fn artifacts_dir(mut self, dir: impl Into<String>) -> Self {
        self.cfg.artifacts_dir = dir.into();
        self
    }

    /// Use the quantized (q16) model artifacts.
    pub fn quantized(mut self, on: bool) -> Self {
        self.cfg.quantized = on;
        self
    }

    /// Use exact L2 FPS + ball query instead of the approximate pipeline
    /// (the Fig. 12(a) ablation switch).
    pub fn exact_sampling(mut self, on: bool) -> Self {
        self.cfg.exact_sampling = on;
        self
    }

    /// Worker threads for the scheduler's warm/prefetch phase.
    pub fn tile_parallelism(mut self, n: usize) -> Self {
        self.cfg.tile_parallelism = n;
        self
    }

    /// Engine implementation tier ([`Fidelity::BitExact`] by default).
    pub fn fidelity(mut self, fidelity: Fidelity) -> Self {
        self.cfg.fidelity = fidelity;
        self
    }

    /// Index-backed pruned spatial-query kernels (on by default): the
    /// median-partition FPS/lattice/kNN kernels on tiers that support
    /// them, and the float-index FPS/ball-query kernels on the
    /// exact-sampling ablation. Byte-identical outputs and accounting,
    /// less host work — `prune(false)` forces the full-scan reference
    /// loops, the bench's comparison axis.
    pub fn prune(mut self, on: bool) -> Self {
        self.cfg.prune = on;
        self
    }

    /// Pipeline dataflow ([`Dataflow::GatherFirst`] — the paper's flow —
    /// by default): `Dataflow::Delayed` runs each level's MLP once over
    /// the unique points and aggregates over the CSR groups afterwards
    /// (Mesorasi-style), with its own closed-form cycle/energy model.
    pub fn dataflow(mut self, dataflow: Dataflow) -> Self {
        self.cfg.dataflow = dataflow;
        self
    }

    /// Replace the hardware model used for latency/energy pricing.
    pub fn hardware(mut self, hw: HardwareConfig) -> Self {
        self.hw = hw;
        self
    }

    /// Reuse an existing executor + metadata instead of re-opening the
    /// artifacts directory — the serving engine's per-lane path: every
    /// lane gets its own `Pipeline` (engine models are single-owner)
    /// while all lanes share one thread-safe executor, i.e. one weight
    /// store and one prepared-artifact cache.
    pub fn share_executor(mut self, meta: Meta, exec: Arc<dyn Executor>) -> Self {
        self.shared = Some((meta, exec));
        self
    }

    /// The pipeline configuration accumulated so far.
    pub fn config(&self) -> &PipelineConfig {
        &self.cfg
    }

    /// Build a single [`Pipeline`] (opens the artifacts directory unless
    /// an executor is shared in).
    pub fn build(self) -> Result<Pipeline> {
        let rt = match self.shared {
            Some((meta, exec)) => Runtime::with_shared(&self.cfg.artifacts_dir, meta, exec),
            None => Runtime::new(&self.cfg.artifacts_dir)
                .with_context(|| format!("loading artifacts from {:?}", self.cfg.artifacts_dir))?,
        };
        Ok(Pipeline::from_parts(rt, self.hw, self.cfg))
    }

    /// Build the single-threaded [`BatchScheduler`] around one pipeline
    /// (`tile_parallelism` sizes its warm-phase worker pool).
    pub fn build_scheduler(self) -> Result<BatchScheduler> {
        Ok(BatchScheduler::around(self.build()?))
    }

    /// Build the shard-parallel [`ServeEngine`]: validates `serve_cfg`,
    /// opens the artifacts directory once, then gives each of the
    /// `serve_cfg.workers` lanes its own pipeline around the one shared
    /// executor (lanes never hold a redundant copy of the weights).
    pub fn build_serve(self, serve_cfg: ServeConfig) -> Result<ServeEngine> {
        serve_cfg.validate()?;
        let hw = self.hw;
        let cfg = self.cfg.clone();
        // Bootstrap pipeline: opens the artifacts directory (or adopts an
        // already-shared executor), picks the backend, builds the one
        // executor everything shares. Dropped after lane construction.
        let boot = self.build()?;
        let exec = boot.executor();
        // Lanes only need the geometry/artifact inventory; the fp32
        // weight stacks live once, inside the shared executor — strip
        // them before fanning the metadata out so no lane (lane 0
        // included) holds a redundant copy of the model.
        let mut meta = boot.meta().clone();
        meta.weights = None;
        let lanes = (0..serve_cfg.workers)
            .map(|_| {
                PipelineBuilder::from_config(cfg.clone())
                    .hardware(hw)
                    .share_executor(meta.clone(), exec.clone())
                    .build()
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(ServeEngine::from_lanes(lanes, serve_cfg.queue_depth))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hermetic() -> PipelineBuilder {
        PipelineBuilder::new().artifacts_dir(
            std::env::temp_dir()
                .join("pc2im-builder-no-artifacts")
                .to_string_lossy()
                .into_owned(),
        )
    }

    #[test]
    fn builder_options_land_in_config() {
        let b = hermetic()
            .quantized(true)
            .exact_sampling(true)
            .tile_parallelism(5)
            .fidelity(Fidelity::Fast)
            .dataflow(Dataflow::Delayed);
        assert!(b.config().quantized);
        assert!(b.config().exact_sampling);
        assert_eq!(b.config().tile_parallelism, 5);
        assert_eq!(b.config().fidelity, Fidelity::Fast);
        assert_eq!(b.config().dataflow, Dataflow::Delayed);
    }

    #[test]
    fn builds_pipeline_hermetically() {
        let p = hermetic().build().unwrap();
        assert_eq!(p.backend(), "reference");
        assert_eq!(p.meta().model.n_points, 1024);
    }

    #[test]
    fn shared_executor_is_one_instance() {
        let boot = hermetic().build().unwrap();
        let exec = boot.executor();
        let mut meta = boot.meta().clone();
        meta.weights = None;
        let lane = hermetic().share_executor(meta, exec.clone()).build().unwrap();
        assert!(Arc::ptr_eq(&exec, &lane.executor()));
    }

    #[test]
    fn build_serve_rejects_zero_workers() {
        let err = hermetic()
            .build_serve(ServeConfig { workers: 0, ..ServeConfig::default() })
            .map(|_| ())
            .unwrap_err();
        assert!(err.to_string().contains("--workers 0"), "{err}");
    }
}
