//! Bench for Fig. 12(b): regenerates the preprocessing-energy table and
//! times both the analytic sweep and the *bit-exact* engine simulation of
//! one tile (the expensive path the analytic model summarizes).
//!
//! Run with: `cargo bench --bench fig12b_preprocessing`

#[path = "harness.rs"]
mod harness;

use pc2im::cim::apd_cim::{ApdCim, ApdCimConfig};
use pc2im::cim::max_cam::{CamArray, CamConfig};
use pc2im::coordinator::Pipeline;
use pc2im::experiments;
use pc2im::pointcloud::synthetic::make_street_cloud;
use pc2im::quant::quantize_cloud;

fn main() {
    // the figure itself
    experiments::run("fig12b", "artifacts").unwrap();

    harness::header("Fig. 12(b) machinery");
    harness::bench("analytic 3-scale preprocessing-energy sweep", 50, || {
        pc2im::experiments::fig12b::preprocessing_energy()
    });

    let tile = quantize_cloud(&make_street_cloud(2048, 3));
    harness::bench("bit-exact APD+CAM FPS, 2048-pt tile, 512 samples", 5, || {
        let mut apd = ApdCim::new(ApdCimConfig::default());
        apd.load_tile(&tile);
        let mut cam = CamArray::new(CamConfig::default());
        Pipeline::cam_fps(&mut apd, &mut cam, 512, 0)
    });
    harness::bench("APD-CIM single full-array scan (2048 dists)", 200, || {
        let mut apd = ApdCim::new(ApdCimConfig::default());
        apd.load_tile(&tile);
        apd.scan_distances(0)
    });
}
