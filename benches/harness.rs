//! Criterion-lite timing harness shared by all bench targets (criterion is
//! not in the offline vendored crate set). Each bench is a `harness =
//! false` binary that includes this file via `#[path]`.
//!
//! Smoke mode — used by CI so bench bit-rot fails the build instead of
//! being discovered at measurement time — clamps every bench to a single
//! iteration. Enable it with the `PC2IM_BENCH_SMOKE` env var or a
//! `--smoke` argument. Set `PC2IM_BENCH_JSON=<path>` to append one JSON
//! line per bench (name/iters/min/mean/max seconds) for trend tracking;
//! see BENCH_seed.json for the committed deterministic baseline.

use std::io::Write as _;
use std::time::Instant;

/// True when the smoke lane asked for minimal iteration counts.
pub fn smoke_mode() -> bool {
    std::env::var_os("PC2IM_BENCH_SMOKE").is_some() || std::env::args().any(|a| a == "--smoke")
}

fn effective_iters(requested: usize) -> usize {
    if smoke_mode() {
        1
    } else {
        requested.max(1)
    }
}

/// Time `f` with warmup; prints min/mean/max over the effective iteration
/// count and returns the mean seconds.
pub fn bench<R>(name: &str, iters: usize, mut f: impl FnMut() -> R) -> f64 {
    let iters = effective_iters(iters);
    // warmup
    std::hint::black_box(f());
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
    }
    let min = samples.iter().cloned().fold(f64::MAX, f64::min);
    let max = samples.iter().cloned().fold(0.0, f64::max);
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    println!(
        "{name:56} {:>10} {:>10} {:>10}   ({iters} iters)",
        fmt(min),
        fmt(mean),
        fmt(max)
    );
    record_json(name, iters, min, mean, max);
    mean
}

pub fn header(title: &str) {
    println!("\n### {title}");
    println!("{:56} {:>10} {:>10} {:>10}", "benchmark", "min", "mean", "max");
}

fn fmt(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1} us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.2} s", s)
    }
}

/// Append a JSON line for this result when PC2IM_BENCH_JSON is set.
fn record_json(name: &str, iters: usize, min: f64, mean: f64, max: f64) {
    let Some(path) = std::env::var_os("PC2IM_BENCH_JSON") else {
        return;
    };
    let escaped: String = name
        .chars()
        .flat_map(|c| match c {
            '"' | '\\' => vec!['\\', c],
            _ => vec![c],
        })
        .collect();
    let line = format!(
        "{{\"name\": \"{escaped}\", \"iters\": {iters}, \"min_s\": {min:e}, \"mean_s\": {mean:e}, \"max_s\": {max:e}}}\n"
    );
    if let Ok(mut f) = std::fs::OpenOptions::new().create(true).append(true).open(&path) {
        let _ = f.write_all(line.as_bytes());
    }
}
