//! Bench for Fig. 12(c): regenerates the CIM design-metric sweep and times
//! the bit-exact MAC datapaths (SC vs BS vs BT) on identical dot products.
//!
//! Run with: `cargo bench --bench fig12c_sccim`

#[path = "harness.rs"]
mod harness;

use pc2im::cim::bs_cim::BsCim;
use pc2im::cim::bt_cim::BtCim;
use pc2im::cim::sc_cim::{ScCim, ScCimConfig};
use pc2im::experiments;
use pc2im::rng::Rng64;

fn main() {
    experiments::run("fig12c", "artifacts").unwrap();

    let mut rng = Rng64::new(1);
    let x: Vec<u16> = (0..4096).map(|_| rng.next_u64() as u16).collect();
    let w: Vec<i16> = (0..4096).map(|_| rng.next_u64() as i16).collect();

    harness::header("bit-exact MAC datapath simulations (4096-elem dot)");
    harness::bench("SC-CIM  (4-bit cluster select/concat)", 50, || {
        ScCim::new(ScCimConfig::default()).dot(&x, &w)
    });
    harness::bench("BS-CIM  (bit-serial)", 50, || BsCim::new().dot(&x, &w));
    harness::bench("BT-CIM  (radix-4 Booth)", 50, || BtCim::new().dot(&x, &w));
    harness::bench("FoM sweep across 6 SCR points", 200, || {
        pc2im::experiments::fig12c::SCRS.map(pc2im::experiments::fig12c::sweep_point)
    });
}
