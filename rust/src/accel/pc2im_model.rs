//! PC2IM analytic model: MSP tiling + APD-CIM sampling + Ping-Pong-MAX CAM
//! + lattice query for preprocessing, SC-CIM for feature computing, with
//! tile-level pipelining between the two stages (Fig. 3(b)).
//!
//! Event formulas mirror exactly what the bit-exact engines charge per
//! operation (`cim/apd_cim.rs`, `cim/max_cam.rs`); `experiments/claims.rs`
//! cross-checks the two at small scale.

use super::{Accelerator, RunCost, StageCost};
use crate::config::HardwareConfig;
use crate::energy::Event;
use crate::network::pointnet2::NetworkDef;
use crate::quant::TD_BITS;

/// The proposed accelerator.
#[derive(Debug, Clone, Copy, Default)]
pub struct Pc2imModel;

impl Pc2imModel {
    /// Preprocessing cost of one SA layer on the APD-CIM + CAM engines.
    fn sa_layer_preproc(n_in: u64, n_out: u64, hw: &HardwareConfig, cost: &mut StageCost) {
        let cap = hw.tile_capacity as u64;
        let tile = n_in.min(cap);
        let row_rate = 16u64; // APD distances per cycle (one PTG row)
        let scan_cycles = tile.div_ceil(row_rate);

        // --- FPS sampling ---
        // Per iteration: one APD full-tile scan (pipelined into the CAM
        // min-update), one 19-cycle bit-CAM max + 1 data-CAM cycle.
        let cam_cycles = TD_BITS as u64 + 1;
        cost.cycles += n_out * (scan_cycles + cam_cycles);
        // Events: every resident point gets a distance + a CAM min-update
        // per iteration; the bit search touches ~2x the live set in total
        // across its 19 cycles (the active set decays geometrically).
        let dist_ops = n_out * tile;
        cost.ledger.charge(Event::ApdDistanceOp, dist_ops);
        cost.ledger.charge(Event::CamComparePair, dist_ops);
        cost.ledger.charge(Event::CamWriteBit, dist_ops * TD_BITS as u64);
        cost.ledger.charge(Event::CamSearchCell, n_out * 2 * tile);

        // --- lattice query ---
        // One APD scan per centroid; hits go through the sorter (register
        // traffic, 19-bit distances + 11-bit indices).
        cost.cycles += n_out * scan_cycles;
        cost.ledger.charge(Event::ApdDistanceOp, n_out * tile);
        cost.ledger.charge(Event::RegBit, n_out * 32 * (TD_BITS as u64 + 11));
    }
}

impl Accelerator for Pc2imModel {
    fn name(&self) -> &'static str {
        "PC2IM"
    }

    fn run(&self, net: &NetworkDef, hw: &HardwareConfig) -> RunCost {
        let mut pre = StageCost::default();

        // Raw cloud streams from DRAM exactly once (MSP tiles are loaded
        // tile-by-tile into the APD array).
        let n0 = net.sa_layers.first().map(|l| l.n_in as u64).unwrap_or(0);
        pre.ledger.charge(Event::DramBit, n0 * 48);
        pre.cycles += (n0 * 48).div_ceil(hw.dram_bits_per_cycle);

        for l in &net.sa_layers {
            if l.n_out > 1 {
                Self::sa_layer_preproc(l.n_in as u64, l.n_out as u64, hw, &mut pre);
            }
        }

        // FP-layer kNN on the APD array: each fine query scans its
        // MSP-co-located coarse tile.
        for l in &net.fp_layers {
            let tiles_fine = (l.n_fine as u64).div_ceil(hw.tile_capacity as u64);
            let coarse_tile = (l.n_coarse as u64 / tiles_fine).max(16);
            let scan = coarse_tile.div_ceil(16);
            pre.cycles += l.n_fine as u64 * scan;
            pre.ledger.charge(Event::ApdDistanceOp, l.n_fine as u64 * coarse_tile);
            pre.ledger
                .charge(Event::RegBit, l.n_fine as u64 * (l.k as u64) * (TD_BITS as u64 + 11));
        }

        // --- feature computing on SC-CIM ---
        let mut feat = StageCost::default();
        let macs = net.total_macs();
        feat.ledger.charge(Event::MacSc, macs);
        let waves = macs.div_ceil(hw.parallel_macs());
        feat.cycles += waves * 4; // 4 input-cluster cycles per wave
        // Intermediate features spill through the 512 KB SRAM once per
        // layer boundary (delayed aggregation keeps them small).
        let feat_bits: u64 = net
            .sa_layers
            .iter()
            .map(|l| (l.n_out * l.mlp.last().unwrap()) as u64 * 16)
            .sum();
        feat.ledger.charge(Event::SramBit, 2 * feat_bits);

        RunCost { preprocessing: pre, feature: feat, pipelined: true }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::network::pointnet2::NetworkDef;

    #[test]
    fn large_workload_sane_latency() {
        let hw = HardwareConfig::default();
        let net = NetworkDef::pointnet2_s(16384);
        let rc = Pc2imModel.run(&net, &hw);
        let ms = rc.latency_s(&hw) * 1e3;
        // The paper's design targets real-time large-scale PCs: single-digit
        // milliseconds at 250 MHz.
        assert!((1.0..30.0).contains(&ms), "latency {ms:.2} ms");
    }

    #[test]
    fn dram_charged_once() {
        let hw = HardwareConfig::default();
        let net = NetworkDef::pointnet2_s(16384);
        let rc = Pc2imModel.run(&net, &hw);
        assert_eq!(rc.preprocessing.ledger.count(Event::DramBit), 16384 * 48);
    }

    #[test]
    fn preproc_energy_dominated_by_apd_not_sram() {
        let hw = HardwareConfig::default();
        let net = NetworkDef::pointnet2_s(16384);
        let rc = Pc2imModel.run(&net, &hw);
        let c = hw.energy();
        let apd = rc.preprocessing.ledger.energy_of_pj(Event::ApdDistanceOp, &c);
        let sram = rc.preprocessing.ledger.energy_of_pj(Event::SramBit, &c);
        assert!(apd > sram, "CIM should replace SRAM traffic");
    }
}
